"""Fitted-model artifacts: serialization, provenance, discovery, loading.

Layout: ``artifacts/calib/<hardware>/<operator>.json`` — one fitted
RandomForest per (hardware, operator), carrying the model geometry it was
fitted for, the oracle that produced the ground truth, held-out error
metrics, and a spec-hash provenance digest (sha256 of the canonical
fitting configuration — same recipe as ``SimSpec.spec_hash``).

``load_calibrated_ops`` turns a directory of artifacts into a
``RefinedModels`` instance for ``build()``; every failure mode raises
``CalibrationError`` with an actionable message (the api layer re-raises
as ``SpecError``).
"""
from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.hardware import HardwareSpec
from repro.core.opmodels.calibration import FittedAttention, FittedGroupedGemm
from repro.core.opmodels.forest import RandomForest
from repro.core.opmodels.kernelsim import VirtualKernels
from repro.core.opmodels.refined import RefinedModels

ARTIFACT_VERSION = 1
OPERATORS = ("attention", "grouped_gemm")


class CalibrationError(ValueError):
    """Artifact missing / corrupt / fitted for different hardware-geometry."""


@dataclass
class CalibrationArtifact:
    operator: str                  # "attention" | "grouped_gemm"
    hardware: str                  # HardwareSpec.name it was fitted on
    model: str                     # model config name (provenance only)
    oracle: str                    # oracle backend that supplied truth
    geometry: Dict[str, int]       # operator geometry the fit is valid for
    seed: int
    n_train: int
    metrics: Dict[str, float]      # held-out fitted error stats
    forest: Dict                   # RandomForest.to_dict()
    spec_hash: str = ""
    created_at: str = ""
    version: int = ARTIFACT_VERSION

    def provenance_hash(self) -> str:
        """16-hex digest of everything that determines the fit (not the
        timestamp): re-running calibrate with the same inputs must produce
        the same hash."""
        blob = json.dumps(
            {"operator": self.operator, "hardware": self.hardware,
             "model": self.model, "oracle": self.oracle,
             "geometry": self.geometry, "seed": self.seed,
             "n_train": self.n_train, "version": self.version},
            sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def to_dict(self) -> Dict:
        return {"operator": self.operator, "hardware": self.hardware,
                "model": self.model, "oracle": self.oracle,
                "geometry": self.geometry, "seed": self.seed,
                "n_train": self.n_train, "metrics": self.metrics,
                "spec_hash": self.spec_hash, "created_at": self.created_at,
                "version": self.version, "forest": self.forest}

    @classmethod
    def from_dict(cls, data: Dict) -> "CalibrationArtifact":
        missing = [k for k in ("operator", "hardware", "geometry", "forest")
                   if k not in data]
        if missing:
            raise CalibrationError(f"artifact missing field(s) {missing}")
        return cls(operator=data["operator"], hardware=data["hardware"],
                   model=data.get("model", "?"),
                   oracle=data.get("oracle", "?"),
                   geometry={k: int(v)
                             for k, v in data["geometry"].items()},
                   seed=int(data.get("seed", 0)),
                   n_train=int(data.get("n_train", 0)),
                   metrics=data.get("metrics", {}),
                   forest=data["forest"],
                   spec_hash=data.get("spec_hash", ""),
                   created_at=data.get("created_at", ""),
                   version=int(data.get("version", ARTIFACT_VERSION)))

    def to_fitted(self):
        """Rehydrate the fitted predictor this artifact serializes."""
        forest = RandomForest.from_dict(self.forest)
        g = self.geometry
        if self.operator == "attention":
            return FittedAttention(forest, g["n_heads"], g["n_kv_heads"],
                                   g["head_dim"])
        if self.operator == "grouped_gemm":
            return FittedGroupedGemm(forest, g["d_in"], g["d_out"])
        raise CalibrationError(f"unknown operator {self.operator!r}")


def artifact_path(root: str, hardware: str, operator: str) -> str:
    return os.path.join(root, hardware, f"{operator}.json")


def save_artifact(art: CalibrationArtifact, root: str) -> str:
    if not art.spec_hash:
        art.spec_hash = art.provenance_hash()
    path = artifact_path(root, art.hardware, art.operator)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(art.to_dict(), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_artifact(path: str) -> CalibrationArtifact:
    if not os.path.isfile(path):
        raise CalibrationError(
            f"no calibration artifact at {path!r}; run "
            f"`python -m repro calibrate` to fit one")
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CalibrationError(f"unreadable artifact {path!r}: {e}") from e
    art = CalibrationArtifact.from_dict(data)
    if art.version != ARTIFACT_VERSION:
        raise CalibrationError(
            f"artifact {path!r} has version {art.version}, this build "
            f"reads version {ARTIFACT_VERSION}; re-run "
            f"`python -m repro calibrate`")
    return art


def discover_artifacts(root: str = os.path.join("artifacts", "calib")
                       ) -> List[Dict]:
    """Lightweight listing (no forest rehydration) for ``repro list``."""
    found = []
    if not os.path.isdir(root):
        return found
    for hw in sorted(os.listdir(root)):
        hw_dir = os.path.join(root, hw)
        if not os.path.isdir(hw_dir):
            continue
        for fn in sorted(os.listdir(hw_dir)):
            if not fn.endswith(".json"):
                continue
            path = os.path.join(hw_dir, fn)
            try:
                with open(path) as f:
                    data = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            found.append({"hardware": hw,
                          "operator": data.get("operator", fn[:-5]),
                          "model": data.get("model", "?"),
                          "oracle": data.get("oracle", "?"),
                          "spec_hash": data.get("spec_hash", ""),
                          "mape": (data.get("metrics") or {}).get("mape"),
                          "path": path})
    return found


def _check_geometry(art: CalibrationArtifact, want: Dict[str, int],
                    path: str, model_name: str) -> None:
    if art.geometry != want:
        raise CalibrationError(
            f"artifact {path!r} was fitted for {art.model!r} geometry "
            f"{art.geometry}, but the spec's model {model_name!r} needs "
            f"{want}; re-run `python -m repro calibrate --model "
            f"{model_name}` (add --smoke for smoke-model specs)")


def load_calibrated_ops(root: str, cfg, hw: HardwareSpec) -> RefinedModels:
    """Build a RefinedModels priced by the fitted artifacts under ``root``.

    ``root`` is an artifact directory: either the calib root (containing a
    ``<hardware>/`` subdirectory) or a hardware directory itself.  The
    attention artifact is required; grouped_gemm is required only for MoE
    model configs.  Artifacts are fitted at the model's tp=1 operator
    geometry — sharded clusters fall back to the virtual-kernel model for
    the sharded shapes (the RefinedModels geometry guard).
    """
    if not os.path.isdir(root):
        raise CalibrationError(
            f"calibration directory {root!r} does not exist; run "
            f"`python -m repro calibrate` to create it")
    hw_dir = os.path.join(root, hw.name)
    base = hw_dir if os.path.isdir(hw_dir) else root
    from repro.calib.grid import geometry_of, moe_geometry_of

    attn_path = os.path.join(base, "attention.json")
    if not os.path.isfile(attn_path):
        have = sorted(d for d in os.listdir(root)
                      if os.path.isdir(os.path.join(root, d)))
        raise CalibrationError(
            f"no attention artifact for hardware {hw.name!r} under "
            f"{root!r} (calibrated hardware dirs: {have or 'none'}); run "
            f"`python -m repro calibrate --hardware {hw.name}`")
    art = load_artifact(attn_path)
    if art.hardware != hw.name:
        raise CalibrationError(
            f"artifact {attn_path!r} was fitted on hardware "
            f"{art.hardware!r}, but the spec targets {hw.name!r}; re-run "
            f"`python -m repro calibrate --hardware {hw.name}`")
    _check_geometry(art, geometry_of(cfg), attn_path, cfg.name)
    attention = art.to_fitted()

    grouped = None
    moe_geo = moe_geometry_of(cfg)
    if moe_geo is not None:
        gg_path = os.path.join(base, "grouped_gemm.json")
        gg = load_artifact(gg_path)
        if gg.hardware != hw.name:
            raise CalibrationError(
                f"artifact {gg_path!r} was fitted on hardware "
                f"{gg.hardware!r}, but the spec targets {hw.name!r}")
        # the fit only depends on the expert dims; expert count / top_k are
        # provenance, so match on the pricing-relevant subset
        want = {"d_in": moe_geo["d_in"], "d_out": moe_geo["d_out"]}
        got = {k: gg.geometry.get(k) for k in want}
        if got != want:
            raise CalibrationError(
                f"artifact {gg_path!r} was fitted for expert dims {got}, "
                f"but {cfg.name!r} needs {want}")
        grouped = gg.to_fitted()

    return RefinedModels(hw, attention=attention, grouped=grouped,
                         kernels=VirtualKernels(hw))
