"""The scan-aware HLO cost parser: corrected totals must match unrolled."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _costs(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return hlo_cost.analyze(comp.as_text()), comp


def test_scan_flops_match_unrolled():
    N = 6
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((N, 128, 128), jnp.float32)

    def body(c, w):
        return jnp.tanh(c @ w), None

    def f_scan(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    def f_unroll(x, ws):
        for i in range(N):
            x, _ = body(x, ws[i])
        return x

    c_scan, comp = _costs(f_scan, x, ws)
    c_unroll, _ = _costs(f_unroll, x, ws)
    assert c_scan["flops"] == pytest.approx(c_unroll["flops"], rel=0.01)
    # raw cost_analysis undercounts the scan (the bug this parser fixes)
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca["flops"] < c_scan["flops"] / (N - 1)


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 48), jnp.float32)
    c, _ = _costs(lambda a, b: a @ b, a, b)
    assert c["flops"] == pytest.approx(2 * 32 * 64 * 48, rel=1e-6)


def test_nested_scan_multiplies_trip_counts():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def inner(c, _):
        return jnp.tanh(c @ c), None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c, _ = _costs(f, x)
    assert c["flops"] == pytest.approx(12 * 2 * 16 * 16 * 16, rel=0.01)


def test_dus_bytes_not_quadratic():
    """Scan ys-accumulation must be charged per-slice, not per-buffer."""
    N, D = 64, 256
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def f(x):
        def body(c, _):
            c = jnp.tanh(c)
            return c, c        # ys: (N, D, D) accumulator
        _, ys = jax.lax.scan(body, x, None, length=N)
        return ys

    c, _ = _costs(f, x)
    buf = N * D * D * 4
    # in-place model: O(N * slice) == O(buf); quadratic would be N * buf
    assert c["bytes"] < 8 * buf
