"""Configuration system for the repro framework.

Every assigned architecture is a :class:`ModelConfig` instance; input shapes
are :class:`ShapeConfig` instances.  Configs are plain frozen dataclasses so
they hash, compare, and serialize trivially (the launcher round-trips them to
JSON in checkpoint metadata).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds (the per-layer pattern a model cycles through)
# ---------------------------------------------------------------------------
ATTN_GLOBAL = "global"      # full (causal) attention
ATTN_LOCAL = "local"        # sliding-window attention
RECURRENT = "recurrent"     # RG-LRU recurrent block (recurrentgemma)
RWKV = "rwkv"               # RWKV6 time-mix + channel-mix block

FAMILIES = ("dense", "moe", "ssm", "vlm", "audio", "hybrid")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    capacity_factor_train: float = 1.25
    capacity_factor_eval: float = 2.0
    router_jitter: float = 0.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None    # default: d_model // num_heads
    # Per-layer pattern, cycled to num_layers.  ("global",) means uniform.
    block_pattern: Tuple[str, ...] = (ATTN_GLOBAL,)
    sliding_window: int = 0           # window for ATTN_LOCAL blocks
    rope_theta: float = 10_000.0
    qk_norm: bool = False             # qwen3-style per-head q/k RMSNorm
    attn_logit_softcap: float = 0.0   # gemma2: 50.0
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    post_block_norm: bool = False     # gemma2 sandwich norms
    gated_mlp: bool = True            # SwiGLU/GeGLU vs plain 2-layer FFN
    mlp_act: str = "silu"             # "silu" | "gelu" | "relu"
    tie_embeddings: bool = False
    rms_eps: float = 1e-6

    moe: Optional[MoEConfig] = None

    # Encoder-decoder (seamless): num_layers == decoder layers.
    encoder_layers: int = 0
    cross_attention: bool = False

    # Modality frontend stubs. "none" | "patch" (vlm) | "frames" (audio).
    frontend: str = "none"
    frontend_dim: int = 0             # embedding dim produced by the stub
    frontend_fraction: float = 0.25   # fraction of seq taken by stub embeds

    # RWKV6 / RG-LRU specifics
    rwkv_head_size: int = 64
    conv1d_width: int = 4             # recurrentgemma temporal conv width
    rglru_c: float = 8.0              # RG-LRU decay sharpness constant

    # --------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def pattern(self) -> Tuple[str, ...]:
        """Full per-layer block-kind tuple of length num_layers."""
        p = self.block_pattern
        reps = (self.num_layers + len(p) - 1) // len(p)
        return (p * reps)[: self.num_layers]

    @property
    def is_attention_free(self) -> bool:
        return all(k in (RWKV, RECURRENT) for k in self.pattern)

    @property
    def is_sub_quadratic(self) -> bool:
        """True if decode memory/compute does not grow unboundedly with ctx."""
        return all(
            k in (RWKV, RECURRENT) or (k == ATTN_LOCAL and self.sliding_window > 0)
            for k in self.pattern
        )

    def kv_cache_len(self, seq_len: int, kind: str) -> int:
        """Per-layer KV length a decode cache must hold for `seq_len` context."""
        if kind in (RWKV, RECURRENT):
            return 0
        if kind == ATTN_LOCAL and self.sliding_window > 0:
            return min(self.sliding_window, seq_len)
        return seq_len

    # Parameter counting (used for MODEL_FLOPS=6ND and memory budgeting).
    def param_count(self, *, active_only: bool = False) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.padded_vocab * d
        total = emb if self.tie_embeddings else 2 * emb
        def attn_params() -> int:
            qkv = d * (self.q_dim + 2 * self.kv_dim)
            out = self.q_dim * d
            qknorm = 2 * hd if self.qk_norm else 0
            return qkv + out + qknorm
        def dense_mlp(ff: int) -> int:
            return d * ff * (3 if self.gated_mlp else 2)
        def rwkv_block() -> int:
            # time-mix: r,k,v,g,o projections + decay lora (d->64->d) + mixes
            tm = 5 * d * d + 2 * d * 64 + 64 * d + 6 * d
            cm = 2 * d * self.d_ff // 2 if False else d * self.d_ff + self.d_ff * d
            return tm + cm
        def rglru_block() -> int:
            # in/out proj (d->dr x2 gates) + conv1d + lru params
            dr = self.d_model  # recurrent width == d_model
            return 2 * d * dr + dr * d + self.conv1d_width * dr + 2 * dr
        per_layer = 0
        for kind in self.pattern:
            norms = 2 * d * (2 if self.post_block_norm else 1)
            if kind == RWKV:
                per_layer += rwkv_block() + norms
                continue
            if kind == RECURRENT:
                per_layer += rglru_block() + dense_mlp(self.d_ff) + norms
                continue
            blk = attn_params()
            if self.moe is not None:
                e = self.moe
                n_e = (e.top_k + e.num_shared_experts) if active_only else (
                    e.num_experts + e.num_shared_experts)
                blk += d * e.num_experts  # router
                blk += n_e * d * e.expert_d_ff * (3 if self.gated_mlp else 2)
            else:
                blk += dense_mlp(self.d_ff)
            per_layer += blk + norms
        total += per_layer
        if self.encoder_layers:
            enc = self.encoder_layers * (attn_params() + dense_mlp(self.d_ff) + 2 * d)
            xattn = self.num_layers * (attn_params() + d)  # cross-attn per dec layer
            total += enc + xattn
        total += d  # final norm
        return total

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), default=str)


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set for the LM pool)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(applicable, reason-if-not).  See DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.is_sub_quadratic:
        return False, "pure full-attention arch: 500k decode KV is unbounded-quadratic territory; skipped per spec"
    return True, ""


# ---------------------------------------------------------------------------
# Reduced ("smoke") variants: tiny same-family configs for CPU tests
# ---------------------------------------------------------------------------
def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny config of the same family/pattern for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        num_layers=min(cfg.num_layers, 2 * max(1, len(cfg.block_pattern))),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        block_pattern=cfg.block_pattern,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        rope_theta=cfg.rope_theta,
        qk_norm=cfg.qk_norm,
        attn_logit_softcap=cfg.attn_logit_softcap,
        final_logit_softcap=cfg.final_logit_softcap,
        post_block_norm=cfg.post_block_norm,
        gated_mlp=cfg.gated_mlp,
        mlp_act=cfg.mlp_act,
        tie_embeddings=cfg.tie_embeddings,
        moe=None,
        encoder_layers=2 if cfg.encoder_layers else 0,
        cross_attention=cfg.cross_attention,
        frontend=cfg.frontend,
        frontend_dim=64 if cfg.frontend_dim else 0,
        frontend_fraction=cfg.frontend_fraction,
        rwkv_head_size=16,
        conv1d_width=cfg.conv1d_width,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(num_experts=4, top_k=2, expert_d_ff=64,
                              num_shared_experts=cfg.moe.num_shared_experts)
    return ModelConfig(**kw)


SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")
