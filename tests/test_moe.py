"""MoE layer: dispatch correctness vs a dense reference, drop accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.models import NO_RULES, init_tree
from repro.models.moe import moe_apply, moe_pds, _capacity


def _dense_reference(cfg, p, x, *, cf):
    """Naive per-token loop implementing the same capacity semantics."""
    moe = cfg.moe
    B, S, D = x.shape
    E, k = moe.num_experts, moe.top_k
    logits = np.asarray(x @ np.asarray(p["router"]), np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    flat = np.asarray(x, np.float64).reshape(-1, D)
    T = flat.shape[0]
    order = np.argsort(-probs.reshape(T, E), axis=1)[:, :k]
    gates = np.take_along_axis(probs.reshape(T, E), order, 1)
    gates /= gates.sum(1, keepdims=True)
    C = _capacity(T, k, E, cf, train=True)
    used = np.zeros(E, int)
    y = np.zeros_like(flat)
    w_in, w_out = np.asarray(p["w_in"], np.float64), np.asarray(p["w_out"], np.float64)
    w_gate = np.asarray(p.get("w_gate"), np.float64) if "w_gate" in p else None
    # assignment priority: same as the kernel — flattened (token, slot) order
    for t in range(T):
        for j in range(k):
            e = order[t, j]
            if used[e] >= C:
                continue
            used[e] += 1
            h = flat[t] @ w_in[e]
            if w_gate is not None:
                g = flat[t] @ w_gate[e]
                h = (g * (1 / (1 + np.exp(-g)))) * h  # silu
            y[t] += gates[t, j] * (h @ w_out[e])
    return y.reshape(B, S, D)


def _tiny_cfg():
    cfg = get_config("mixtral-8x7b", smoke=True)
    return dataclasses.replace(cfg, moe=MoEConfig(num_experts=4, top_k=2,
                                                  expert_d_ff=32,
                                                  capacity_factor_train=1.25))


def test_moe_matches_dense_reference():
    cfg = _tiny_cfg()
    p = init_tree(jax.random.PRNGKey(0), moe_pds(cfg), jnp.float64)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float64)
    y, aux = moe_apply(cfg, p, x, NO_RULES, train=True)
    want = _dense_reference(cfg, p, x, cf=cfg.moe.capacity_factor_train)
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-6, rtol=1e-6)


def test_moe_drop_accounting():
    cfg = _tiny_cfg()
    p = init_tree(jax.random.PRNGKey(0), moe_pds(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    _, aux = moe_apply(cfg, p, x, NO_RULES, train=True)
    assert 0.0 <= float(aux["moe_drop_frac"]) <= 1.0
    assert float(aux["moe_lb_loss"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz


def test_moe_gradients_flow():
    cfg = _tiny_cfg()
    p = init_tree(jax.random.PRNGKey(0), moe_pds(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_apply(cfg, p, x, NO_RULES, train=True)
        return jnp.sum(y ** 2) + 0.01 * aux["moe_lb_loss"]

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
    # router must receive gradient through the lb loss / gates
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
