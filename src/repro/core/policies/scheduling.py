"""Queue-ordering policies for ClusterSchedulers."""
from __future__ import annotations

from typing import List

from repro.core.request import Request


class QueuePolicy:
    name = "base"

    def order(self, queue: List[Request], now: float) -> List[Request]:
        raise NotImplementedError


class FCFS(QueuePolicy):
    name = "fcfs"

    def order(self, queue, now):
        return sorted(queue, key=lambda r: (r.arrival, r.rid))


class SJF(QueuePolicy):
    """Shortest prompt first (reduces head-of-line blocking for prefill)."""
    name = "sjf"

    def order(self, queue, now):
        return sorted(queue, key=lambda r: (r.prompt_len, r.arrival, r.rid))


class Priority(QueuePolicy):
    """External priority in request.timestamps['priority'] (lower first)."""
    name = "priority"

    def order(self, queue, now):
        return sorted(queue, key=lambda r: (r.timestamps.get("priority", 0.0),
                                            r.arrival, r.rid))


POLICIES = {p.name: p for p in (FCFS(), SJF(), Priority())}
