"""Vectorized predictor backend: batch/scalar equivalence, the jit cost
kernel, memo-cache accounting, and backend spec plumbing."""
import numpy as np
import pytest

from repro.api.spec import OpModelSpec, SpecError
from repro.configs import get_config
from repro.core.hardware import H100_SXM, ParallelismConfig
from repro.core.opmodels.analytical import AnalyticalModels
from repro.core.opmodels.batch import batch_step_totals, supports_vectorized
from repro.core.predictor import ExecutionPredictor

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _pred(name="qwen3-8b", tp=1, pp=1, backend="python", **kw):
    cfg = get_config(name, smoke=True)
    return ExecutionPredictor(cfg, ParallelismConfig(tp=tp, pp=pp),
                              H100_SXM, AnalyticalModels(H100_SXM),
                              backend=backend, **kw)


def _grid(rng, n_steps=30):
    steps = []
    for _ in range(n_steps):
        n = int(rng.integers(1, 10))
        q = [int(rng.integers(1, 700)) for _ in range(n)]
        kv = [qi + int(rng.integers(0, 1500)) for qi in q]
        steps.append((q, kv))
    steps.append(([], []))          # zero-token step prices to 0.0
    steps.append(([9], [9]))        # q == kv triggers the causal 0.5
    return steps


def _assert_matches(pred, steps, decode, backend, tol):
    ref = np.array([pred._step_time_impl(list(q), list(kv),
                                         decode=decode).total
                    for q, kv in steps])
    got = pred.step_time_batch(steps, decode=decode, backend=backend)
    rel = np.abs(got - ref) / np.maximum(np.abs(ref), 1e-30)
    rel[ref == 0] = np.abs(got[ref == 0])
    assert rel.max() <= tol, (backend, float(rel.max()))


# ------------------------------------------------------ batch == scalar --
@pytest.mark.parametrize("name,tp,pp", [
    ("qwen3-8b", 1, 1), ("qwen3-8b", 4, 2), ("gemma2-27b", 2, 1),
    ("rwkv6-1.6b", 1, 1), ("recurrentgemma-2b", 1, 2), ("yi-9b", 8, 4),
])
@pytest.mark.parametrize("decode", [False, True])
def test_numpy_batch_matches_scalar_grid(name, tp, pp, decode):
    pred = _pred(name, tp, pp, memoize=False)
    assert supports_vectorized(pred)
    steps = _grid(np.random.default_rng(hash((name, tp, pp)) % 2**32))
    if decode:
        steps = [([1] * len(q), kv) for q, kv in steps]
    _assert_matches(pred, steps, decode, "numpy", 1e-9)


def test_jit_batch_matches_scalar_loosely():
    pytest.importorskip("jax")
    pred = _pred(memoize=False)
    steps = _grid(np.random.default_rng(7), n_steps=12)
    # float32 kernel: ~1e-7 relative, far looser than the float64 path
    _assert_matches(pred, steps, False, "jit", 1e-5)


if HAVE_HYPOTHESIS:
    @given(st.lists(
        st.lists(st.tuples(st.integers(1, 2000), st.integers(0, 4000)),
                 min_size=1, max_size=8),
        min_size=1, max_size=12),
        st.booleans())
    @settings(max_examples=40, deadline=None)
    def test_batch_matches_scalar_property(shape_grid, decode):
        pred = _pred(memoize=False)
        steps = [([q for q, _ in reqs], [q + e for q, e in reqs])
                 for reqs in shape_grid]
        if decode:
            steps = [([1] * len(q), kv) for q, kv in steps]
        _assert_matches(pred, steps, decode, "numpy", 1e-9)


# ------------------------------------------------------- MoE batching --
def _routers():
    from repro.core.routing import (BalancedRouting, TraceRouting,
                                    UniformRouting, ZipfRouting)
    return {
        "balanced": BalancedRouting(),
        "uniform": UniformRouting(),
        "zipf": ZipfRouting(alpha=1.1),
        "trace": TraceRouting([3.0, 1.0, 1.0, 2.0]),
    }


def _moe_pred(tp=2, ep=None, backend="python", router=None, seed=0,
              **moe_over):
    import dataclasses
    cfg = get_config("mixtral-8x7b", smoke=True)
    if moe_over:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, **moe_over))
    par = ParallelismConfig(tp=tp, ep=ep if ep is not None else tp)
    return ExecutionPredictor(cfg, par, H100_SXM,
                              AnalyticalModels(H100_SXM), backend=backend,
                              routing=router, seed=seed, memoize=False)


MOE_STEPS = [([3, 4], [10, 12]), ([1, 1], [50, 60]), ([], []),
             ([17], [400]), ([1] * 6, [64] * 6)]


@pytest.mark.parametrize("router", ["balanced", "uniform", "zipf", "trace"])
@pytest.mark.parametrize("decode", [False, True])
def test_moe_numpy_batch_bit_identical_to_scalar_walk(router, decode):
    vec = _moe_pred(backend="numpy", router=_routers()[router])
    assert supports_vectorized(vec)          # the MoE gate is lifted
    ref_pred = _moe_pred(router=_routers()[router])
    ref = np.array([ref_pred._step_time_impl(list(q), list(kv),
                                             decode=decode).total
                    for q, kv in MOE_STEPS])
    got = vec.step_time_batch(MOE_STEPS, decode=decode, backend="numpy")
    np.testing.assert_array_equal(got, ref)  # bit-for-bit, same RNG order


def test_moe_jit_batch_matches_scalar_closely():
    pytest.importorskip("jax")
    vec = _moe_pred(backend="jit", router=_routers()["zipf"])
    ref_pred = _moe_pred(router=_routers()["zipf"])
    ref = np.array([ref_pred._step_time_impl(list(q), list(kv),
                                             decode=True).total
                    for q, kv in MOE_STEPS])
    got = vec.step_time_batch(MOE_STEPS, decode=True, backend="jit")
    rel = np.abs(got - ref) / np.maximum(np.abs(ref), 1e-30)
    rel[ref == 0] = np.abs(got[ref == 0])
    assert rel.max() <= 1e-6


def test_moe_batch_preserves_rng_draw_order():
    """Pinned draw-order exactness: the batched path must consume
    ``routing.assign`` with the identical (n_tokens, call-index) sequence
    as the scalar walk, leaving the generator in the identical state."""
    from repro.core.routing import UniformRouting

    class LoggingRouter(UniformRouting):
        def __init__(self):
            self.calls = []

        def assign(self, n_tokens, n_experts, top_k, rng):
            self.calls.append((n_tokens, n_experts, top_k))
            return super().assign(n_tokens, n_experts, top_k, rng)

    ra, rb = LoggingRouter(), LoggingRouter()
    vec = _moe_pred(backend="numpy", router=ra)
    ref = _moe_pred(router=rb)
    for q, kv in MOE_STEPS:
        ref._step_time_impl(list(q), list(kv), decode=True)
    vec.step_time_batch(MOE_STEPS, decode=True, backend="numpy")
    assert ra.calls == rb.calls              # same sequence, same order
    # generators advanced identically: next draws coincide bit-for-bit
    np.testing.assert_array_equal(vec.rng.integers(0, 2**31, 8),
                                  ref.rng.integers(0, 2**31, 8))


if HAVE_HYPOTHESIS:
    @given(st.sampled_from([4, 5, 8, 64]),          # num_experts
           st.sampled_from([1, 2, 4]),              # top_k
           st.sampled_from([1, 2, 4, 8]),           # ep
           st.sampled_from([1.0, 1.25, 2.0, 16.0]),  # capacity factor
           st.sampled_from(["balanced", "uniform", "zipf", "trace"]),
           st.lists(st.tuples(st.integers(1, 9), st.integers(1, 300)),
                    min_size=1, max_size=5),
           st.booleans())
    @settings(max_examples=60, deadline=None)
    def test_moe_batch_matches_scalar_property(E, k, ep, cap, router,
                                               shapes, decode):
        router_kw = dict(_routers())
        from repro.core.routing import TraceRouting
        router_kw["trace"] = TraceRouting(np.arange(1.0, E + 1.0))
        steps = [([q] * n, [q + 50] * n) for n, q in shapes]
        if decode:
            steps = [([1] * len(q), kv) for q, kv in steps]
        kw = dict(tp=ep, ep=ep, num_experts=E, top_k=min(k, E),
                  capacity_factor_eval=cap)
        vec = _moe_pred(backend="numpy", router=router_kw[router], **kw)
        ref_pred = _moe_pred(router=router_kw[router], **kw)
        ref = np.array([ref_pred._step_time_impl(list(q), list(kv),
                                                 decode=decode).total
                        for q, kv in steps])
        got = vec.step_time_batch(steps, decode=decode, backend="numpy")
        np.testing.assert_array_equal(got, ref)


def test_moe_numpy_backend_no_longer_falls_back():
    pred = _pred("mixtral-8x7b", tp=2, backend="numpy", memoize=False)
    assert supports_vectorized(pred)
    assert pred._vectorized_ok()


# ----------------------------------------------------------- fallbacks --


def test_overridden_ops_disable_vectorization():
    class TweakedOps(AnalyticalModels):
        def gemm(self, m, n, k, dtype_bytes=2):
            return super().gemm(m, n, k, dtype_bytes) * 1.5

    cfg = get_config("qwen3-8b", smoke=True)
    pred = ExecutionPredictor(cfg, ParallelismConfig(), H100_SXM,
                              TweakedOps(H100_SXM), memoize=False)
    assert not supports_vectorized(pred)


def test_numpy_backend_prices_cache_misses_identically():
    a = _pred(backend="numpy")
    b = _pred(backend="python")
    qa = a.step_time([7, 9], [100, 200], decode=False).total
    qb = b.step_time([7, 9], [100, 200], decode=False).total
    assert qa == pytest.approx(qb, rel=1e-9)
    assert (a.cache_hits, a.cache_misses) == (0, 1)


def test_empty_batch():
    pred = _pred(memoize=False)
    assert batch_step_totals(pred, [], decode=True).shape == (0,)


# --------------------------------------------------- memo-cache metrics --
def test_cache_hit_miss_counters_and_lru_eviction():
    pred = _pred(cache_size=2)
    shapes = [([10], [10]), ([500], [500]), ([10000], [10000])]
    for q, kv in shapes:                     # 3 distinct buckets, cap 2
        pred.step_time(q, kv, decode=False)
    assert (pred.cache_hits, pred.cache_misses) == (0, 3)
    assert len(pred._cache) == 2             # LRU evicted the oldest
    pred.step_time(*shapes[2], decode=False)     # most-recent: hit
    assert pred.cache_hits == 1
    pred.step_time(*shapes[0], decode=False)     # evicted: miss again
    assert pred.cache_misses == 4
    assert len(pred._cache) == 2


def test_bucket_call_counters_stay_bounded():
    """The stochastic-router rotation counters must be evicted alongside
    the LRU step cache — fleet runs see unboundedly many shape buckets."""
    from repro.core.routing import UniformRouting
    pred = _pred("qwen3-8b", cache_size=4, routing=UniformRouting())
    cap = pred._bucket_calls_cap
    for n in range(1, cap + 200):        # distinct buckets galore
        pred.step_time([n], [n], decode=False)
    assert len(pred._bucket_calls) <= cap
    # deterministic routing keeps no counters at all
    det = _pred("qwen3-8b", cache_size=4)
    for n in range(1, 50):
        det.step_time([n], [n], decode=False)
    assert len(det._bucket_calls) == 0


def test_grouped_gemm_rank_stats_cache_is_exact_and_bounded():
    pred = _moe_pred()
    uncached = _moe_pred()
    uncached._gg_cache_size = 0          # force recomputation every call
    for q, kv in [([5, 5], [30, 30])] * 3 + [([9], [99])]:
        a = pred._step_time_impl(list(q), list(kv), decode=True).total
        b = uncached._step_time_impl(list(q), list(kv), decode=True).total
        assert a == b                    # memo hit bit-identical to miss
    assert len(pred._gg_cache) <= pred._gg_cache_size


def test_report_surfaces_predictor_cache_stats():
    from repro.api import SimSpec, run
    rep = run(SimSpec.from_dict({
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "colocated", "n_replicas": 1},
        "workload": {"n_requests": 10, "rate": 50.0},
    }))
    s = rep.summary
    assert s["predictor_cache_hits"] + s["predictor_cache_misses"] > 0
    assert s["predictor_cache_hit_rate"] == pytest.approx(
        s["predictor_cache_hits"]
        / (s["predictor_cache_hits"] + s["predictor_cache_misses"]))


# ------------------------------------------------------- spec plumbing --
def test_opmodel_backend_validation():
    OpModelSpec(backend="jit").validate()
    with pytest.raises(SpecError, match="backend"):
        OpModelSpec(backend="fortran").validate()
    with pytest.raises(ValueError, match="backend"):
        _pred(backend="fortran")


def test_backend_threads_through_build():
    from repro.api import SimSpec
    from repro.api.run import build
    handle = build(SimSpec.from_dict({
        "model": {"name": "qwen2-7b", "smoke": True},
        "topology": {"preset": "colocated", "n_replicas": 2},
        "opmodel": {"backend": "numpy"},
    }))
    for cluster in handle.clusters.values():
        for w in cluster.replicas:
            assert w.predictor.backend == "numpy"
