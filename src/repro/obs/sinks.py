"""Trace sinks: chrome (Perfetto), jsonl, and text summary backends.

``TraceSink`` is a tiny protocol — ``write(tel, path)`` — so studies and
the CLI can fan one recorded run out to several formats.  The chrome
sink emits Chrome trace-event JSON loadable in Perfetto / ``chrome://
tracing``: instances (or clusters, for single-instance runs) map to
*processes*, replicas and EP ranks map to *threads*, counters become
counter tracks, and all timestamps are non-negative microseconds sorted
monotonically.

:func:`engine_events_to_chrome` is the repaired conversion for raw
engine-event rings (the old ``EventTrace.to_chrome_trace`` emitted
negative ``ts`` whenever an event's duration started before t=0 and
only honoured ``dur`` on BATCH_DONE); ``core/trace.py`` now delegates
here.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Protocol, Tuple

from repro.obs.spans import Span
from repro.obs.telemetry import Telemetry

SPANS_SCHEMA_VERSION = 1


class TraceSink(Protocol):
    def write(self, tel: Telemetry, path: str) -> None: ...


# ---- identity -> pid/tid mapping ------------------------------------------

def _span_scope(tel: Telemetry, s: Span) -> Tuple[str, str]:
    """(process label, thread label) for one span."""
    cluster, instance = tel.replica_info(s.replica)
    pid = instance or cluster or s.meta.get("instance") or "sim"
    rep = s.replica
    if instance and rep.startswith(instance + "/"):
        rep = rep[len(instance) + 1:]    # pid already names the instance
    if s.kind in ("ep_rank", "ep_dispatch"):
        tid = f"{rep}:ep{s.meta.get('rank', '?')}"
    elif rep:
        tid = rep
    else:
        tid = "requests"
    return pid, tid


def _counter_scope(tel: Telemetry, name: str) -> str:
    replica, instance = tel.counters.scope(name)
    if instance:
        return instance
    if replica:
        cluster, inst = tel.replica_info(replica)
        return inst or cluster or "sim"
    return "sim"


def chrome_trace_events(tel: Telemetry) -> List[dict]:
    """Trace-event list: metadata first, then ts-sorted spans/counters."""
    pid_ids: Dict[str, int] = {}
    tid_ids: Dict[Tuple[str, str], int] = {}
    body: List[dict] = []

    def pid_of(label: str) -> int:
        if label not in pid_ids:
            pid_ids[label] = len(pid_ids) + 1
        return pid_ids[label]

    def tid_of(pid_label: str, tid_label: str) -> int:
        key = (pid_label, tid_label)
        if key not in tid_ids:
            tid_ids[key] = sum(1 for p, _ in tid_ids if p == pid_label) + 1
        return tid_ids[key]

    # deterministic numbering: register every identity sorted first
    scopes = sorted({_span_scope(tel, s) for s in tel.spans}
                    | {(_counter_scope(tel, n), "") for n in
                       tel.counters.names()})
    for pid_label, tid_label in scopes:
        pid_of(pid_label)
        if tid_label:
            tid_of(pid_label, tid_label)

    for s in tel.spans:
        pid_label, tid_label = _span_scope(tel, s)
        pid, tid = pid_of(pid_label), tid_of(pid_label, tid_label)
        ts = max(s.start, 0.0) * 1e6
        args = {"rid": s.rid, **s.meta}
        if s.end > s.start:
            dur = (min(s.dur, s.end) if s.start < 0.0 else s.dur) * 1e6
            body.append({"name": s.kind, "ph": "X", "pid": pid, "tid": tid,
                         "ts": ts, "dur": dur, "cat": s.category or "detail",
                         "args": args})
        else:
            body.append({"name": s.kind, "ph": "i", "pid": pid, "tid": tid,
                         "ts": ts, "s": "t", "args": args})
    for name in tel.counters.names():
        pid = pid_of(_counter_scope(tel, name))
        for t, v in tel.counters.series(name):
            body.append({"name": name, "ph": "C", "pid": pid, "tid": 0,
                         "ts": max(t, 0.0) * 1e6, "args": {"value": v}})
    body.sort(key=lambda e: (e["ts"], e["pid"], e.get("tid", 0), e["name"]))

    meta: List[dict] = []
    for label, pid in sorted(pid_ids.items(), key=lambda kv: kv[1]):
        meta.append({"name": "process_name", "ph": "M", "pid": pid,
                     "args": {"name": label}})
    for (pid_label, tid_label), tid in sorted(tid_ids.items(),
                                              key=lambda kv: kv[1]):
        if tid_label:
            meta.append({"name": "thread_name", "ph": "M",
                         "pid": pid_ids[pid_label], "tid": tid,
                         "args": {"name": tid_label}})
    return meta + body


def write_chrome_trace(tel: Telemetry, path: str) -> None:
    with open(path, "w") as f:
        json.dump({"traceEvents": chrome_trace_events(tel),
                   "displayTimeUnit": "ms"}, f)


# ---- jsonl spans -----------------------------------------------------------

def write_spans_jsonl(tel: Telemetry, path: str) -> None:
    """One JSON object per line: a header, every span (with resolved
    identity), then one record per finished request with attribution."""
    with open(path, "w") as f:
        f.write(json.dumps({"type": "header",
                            "version": SPANS_SCHEMA_VERSION,
                            "n_spans": len(tel.spans),
                            "dropped_spans": tel.dropped_spans,
                            "n_requests": len(tel.records)}) + "\n")
        for s in tel.spans:
            d = s.to_dict()
            cluster, instance = tel.replica_info(s.replica)
            d["type"] = "span"
            d["cluster"] = cluster
            d["instance"] = instance
            d["category"] = s.category
            f.write(json.dumps(d) + "\n")
        for rec in tel.records:
            d = rec.to_dict()
            d["type"] = "request"
            f.write(json.dumps(d) + "\n")


def read_spans_jsonl(path: str) -> dict:
    """Round-trip reader: {'header': ..., 'spans': [Span], 'requests':
    [dict]} — what ``examples/trace_study.py`` uses to reconstruct
    critical paths."""
    header = None
    spans: List[Span] = []
    requests: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            t = d.pop("type", "span")
            if t == "header":
                header = d
            elif t == "span":
                spans.append(Span.from_dict(d))
            else:
                requests.append(d)
    return {"header": header or {}, "spans": spans, "requests": requests}


# ---- text summary ----------------------------------------------------------

def render_summary(tel: Telemetry, top_n: int = 5) -> str:
    """Top-N slowest requests with attribution, plus run-level fractions."""
    lines: List[str] = []
    frac = tel.attribution_fractions()
    lines.append(f"requests={len(tel.records)} spans={len(tel.spans)} "
                 f"(dropped={tel.dropped_spans}) "
                 f"counter_series={len(tel.counters)}")
    lines.append("attribution: " + "  ".join(
        f"{k.replace('_frac', '')}={v:.1%}" for k, v in frac.items()))
    lines.append(f"top {top_n} slowest requests:")
    for rec in tel.slowest(top_n):
        a = rec.attribution
        where = f" inst={rec.instance}" if rec.instance else ""
        lines.append(
            f"  rid={rec.rid} e2e={rec.e2e * 1e3:.1f}ms "
            f"ttft={'n/a' if rec.ttft is None else f'{rec.ttft * 1e3:.1f}ms'}"
            f"{where} | queue={a['queue_s'] * 1e3:.1f} "
            f"compute={a['compute_s'] * 1e3:.1f} "
            f"comm={a['comm_s'] * 1e3:.1f} "
            f"preempt={a['preempt_s'] * 1e3:.1f} "
            f"stall={a['stall_s'] * 1e3:.1f} (ms)")
    return "\n".join(lines)


def write_summary(tel: Telemetry, path: str, top_n: int = 5) -> None:
    with open(path, "w") as f:
        f.write(render_summary(tel, top_n) + "\n")


SINKS = {"chrome": write_chrome_trace, "jsonl": write_spans_jsonl,
         "summary": write_summary}


# ---- repaired raw engine-event conversion ---------------------------------

def engine_events_to_chrome(events: Iterable[tuple]) -> List[dict]:
    """Convert an ``EventTrace`` ring — (t, kind, data) tuples — to
    trace events.  Any event whose data carries a numeric ``dur`` (not
    just BATCH_DONE) becomes a duration event; starts are clamped to
    t >= 0 with the duration truncated to match, so ``ts`` is never
    negative."""
    out: List[dict] = []
    for t, kind, data in events:
        dur = data.get("dur") if isinstance(data, dict) else None
        if isinstance(dur, (int, float)) and dur > 0:
            start = t - dur
            if start < 0.0:
                dur += start        # truncate the pre-t=0 portion
                start = 0.0
            name = kind
            if kind == "batch_done":
                name = (f"batch p{data.get('n_prefill', 0)}"
                        f"/d{data.get('n_decode', 0)}")
            out.append({"name": name, "ph": "X", "pid": 0,
                        "tid": data.get("replica", "?"),
                        "ts": start * 1e6, "dur": dur * 1e6})
        else:
            out.append({"name": kind, "ph": "i", "pid": 0, "tid": "events",
                        "ts": max(t, 0.0) * 1e6, "s": "g"})
    out.sort(key=lambda e: e["ts"])
    return out
