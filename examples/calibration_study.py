"""Calibration study: close the sim-to-real loop end to end.

1. Calibrate the refined operator models against an oracle (kernelsim by
   default — swap in the real Pallas kernels with --oracle pallas on an
   accelerator) and print the fitted / analytical / vidur-proxy error
   table on the held-out heterogeneous grid.
2. Run the SAME serving workload twice — analytical roofline vs fitted
   models — and show how much the operator model moves the end-to-end
   numbers the simulator reports.

    PYTHONPATH=src python examples/calibration_study.py [--smoke]
"""
from __future__ import annotations

import argparse

from repro.api import ModelRef, SimSpec, TopologySpec, WorkloadSpec, run
from repro.calib import calibrate

MODEL = "qwen2-7b"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced geometry + grid (CI)")
    ap.add_argument("--oracle", default="kernelsim")
    ap.add_argument("--out", default="artifacts/calib")
    args = ap.parse_args(argv)

    n_train, n_eval = (160, 60) if args.smoke else (600, 150)
    print(f"== calibrating {MODEL} (oracle={args.oracle}, "
          f"n_train={n_train}) ==")
    res = calibrate(model=MODEL, oracle=args.oracle, smoke=args.smoke,
                    n_train=n_train, n_eval=n_eval, out_root=args.out)
    for op, fams in res.fidelity.items():
        print(f"  {op}: held-out relative error")
        for fam in ("fitted", "analytical", "vidur_proxy"):
            s = fams[fam]
            print(f"    {fam:12s} mape={s['mape']:8.3%}  "
                  f"p50={s['p50']:8.3%}  p99={s['p99']:8.3%}")

    wl = WorkloadSpec(n_requests=60 if args.smoke else 200, rate=10.0,
                      prompt_mean=256 if args.smoke else 1024,
                      output_mean=32 if args.smoke else 128)
    base = SimSpec(name="calib-study",
                   model=ModelRef(MODEL, smoke=args.smoke),
                   topology=TopologySpec(preset="colocated", n_replicas=2,
                                         tp=1),
                   workload=wl, seed=0)
    analytical = run(base)
    fitted = run(base.with_(**{"opmodel.name": "refined",
                               "opmodel.calibration": args.out}))
    print("\n== same workload, two operator models ==")
    print(f"{'':24s}{'analytical':>14s}{'fitted':>14s}")
    for key in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s",
                "throughput_tok_s"):
        a, f = analytical.summary.get(key), fitted.summary.get(key)
        if a is not None and f is not None:
            print(f"  {key:22s}{a:14.6g}{f:14.6g}")
    drift = abs(fitted.summary["ttft_p50_s"]
                - analytical.summary["ttft_p50_s"])
    print(f"\nfitted-vs-analytical ttft_p50 drift: {drift * 1e3:.2f} ms "
          f"(the accuracy the analytical roofline leaves on the table)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
