"""Deterministic discrete-event simulation engine.

Events are ordered by (time, seq) — seq is a global monotone counter so
simultaneous events replay in schedule order, making every simulation
bit-reproducible (property-tested).

Hot-path design (the vectorized event core):

- heap entries are ``(time, seq, Event)`` tuples, so ``heapq`` ordering
  resolves with C-level tuple comparison instead of Python ``__lt__``
  dispatch, and :class:`Event` itself is a ``__slots__`` class (no
  per-event dict);
- a bulk **timeline** source (:meth:`schedule_timeline`) holds pre-sorted
  event streams (request arrivals) as plain tuples consumed by index —
  a million arrivals never enter the heap at all, and their Event objects
  materialize lazily at dispatch;
- **same-timestamp batching**: kinds registered through
  :meth:`register_batch_handler` have contiguous runs of events at an
  identical timestamp drained into one list and dispatched as a single
  call.  Only contiguous same-(time, kind) runs are grouped, so the global
  (time, seq) replay order is preserved exactly — with no handlers
  registered the engine is bit-identical to pre-batching behavior.
"""
from __future__ import annotations

import heapq
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.events import EV, Event, _seq


class SimEngine:
    def __init__(self, *, trace: Optional[Callable[[Event], None]] = None,
                 max_events: int = 50_000_000):
        self.now = 0.0
        self._heap: List[Tuple[float, int, Event]] = []
        self._timeline: List[tuple] = []   # (time, seq, kind, fn, data)
        self._tl_i = 0
        self._trace = trace
        self._processed = 0
        self._max_events = max_events
        self._batch_handlers: Dict[EV, Callable[[List[Event]], None]] = {}

    # ------------------------------------------------------------------ API
    def at(self, time: float, kind: EV, fn: Callable[[Event], None],
           **data) -> Event:
        assert time >= self.now - 1e-12, (time, self.now)
        ev = Event(max(time, self.now), kind, fn, data if data else None)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def after(self, delay: float, kind: EV, fn: Callable[[Event], None],
              **data) -> Event:
        return self.at(self.now + max(delay, 0.0), kind, fn, **data)

    def schedule_timeline(self, items: Iterable[Tuple[float, EV,
                                                      Callable, Any]]) -> int:
        """Bulk-schedule a time-sorted event stream without heap traffic.

        ``items`` yields ``(time, kind, fn, data)`` in non-decreasing time
        order (data may be any payload object, not just a dict).  Sequence
        numbers are assigned immediately, in order — ties against events
        pushed with :meth:`at` afterwards break exactly as if every item
        had been pushed here and now.  Returns the number of items added.
        """
        tl = self._timeline
        last = tl[-1][0] if tl else -float("inf")
        n0 = len(tl)
        for time, kind, fn, data in items:
            if time < last:
                raise ValueError(
                    f"timeline items must be sorted by time and follow "
                    f"any previous timeline: {time} < {last} (use at() "
                    f"for out-of-order events)")
            if time < self.now - 1e-12:
                raise ValueError(f"timeline event in the past: "
                                 f"{time} < now={self.now}")
            last = time
            tl.append((time, next(_seq), kind, fn, data))
        return len(tl) - n0

    def register_batch_handler(self, kind: EV,
                               fn: Callable[[List[Event]], None]) -> None:
        """Dispatch contiguous same-timestamp runs of ``kind`` as one call.

        The handler receives the events in schedule (seq) order.  Grouping
        never crosses a different-kind event or a timestamp change, so the
        deterministic replay order is unchanged; only the *call shape*
        differs (one call for N events instead of N calls).
        """
        self._batch_handlers[kind] = fn

    def advance_to(self, time: float) -> None:
        """Advance the clock to ``time`` with no event dispatch (used by
        the windowed fleet mode to bring idle instance engines up to a
        synchronization barrier).  Never rewinds; refuses to skip over
        pending events."""
        if time <= self.now:
            return
        nxt = self.peek_time()
        assert nxt is None or nxt >= time - 1e-12, (nxt, time)
        self.now = time

    def peek_time(self) -> Optional[float]:
        """Timestamp of the next pending event (None when drained)."""
        t = self._heap[0][0] if self._heap else None
        i = self._tl_i
        if i < len(self._timeline):
            t2 = self._timeline[i][0]
            if t is None or t2 < t:
                return t2
        return t

    # ------------------------------------------------------------ run loop
    def run(self, until: float = float("inf")) -> None:
        heap = self._heap
        tl = self._timeline
        trace = self._trace
        batch = self._batch_handlers
        max_events = self._max_events
        pop = heapq.heappop
        n_tl = len(tl)
        while True:
            i = self._tl_i
            if heap:
                entry = heap[0]
                use_tl = (i < n_tl and entry[0] >= tl[i][0]
                          and (tl[i][0], tl[i][1]) < (entry[0], entry[1]))
            elif i < n_tl:
                use_tl = True
            else:
                break
            t = tl[i][0] if use_tl else entry[0]
            if t > until:
                break
            if self._processed >= max_events:
                raise RuntimeError(
                    f"simulation event budget exceeded: max_events="
                    f"{max_events}, processed={self._processed}, "
                    f"pending={self.pending}, now={self.now}")
            if use_tl:
                self._tl_i = i + 1
                item = tl[i]
                kind = item[2]
                ev = Event(t, kind, item[3], item[4], seq=item[1])
            else:
                pop(heap)
                ev = entry[2]
                kind = ev.kind
            self.now = t
            self._processed += 1
            if trace is not None:
                trace(ev)
            if batch and kind in batch:
                evs = [ev]
                self._drain_matching(t, kind, evs)
                batch[kind](evs)
            elif ev.fn is not None:
                ev.fn(ev)
            n_tl = len(tl)   # handlers may have extended the timeline
        if self.pending and self.peek_time() > until:
            self.now = until

    def _drain_matching(self, t: float, kind: EV,
                        out: List[Event]) -> None:
        """Pop the contiguous run of events at time ``t`` of ``kind`` (the
        batch-dispatch tail; stops at the first different kind/time so seq
        order is preserved)."""
        heap, tl, trace = self._heap, self._timeline, self._trace
        while True:
            i = self._tl_i
            nxt_tl = tl[i] if i < len(tl) else None
            nxt_h = heap[0] if heap else None
            if nxt_tl is not None and (
                    nxt_h is None
                    or (nxt_tl[0], nxt_tl[1]) < (nxt_h[0], nxt_h[1])):
                if nxt_tl[0] != t or nxt_tl[2] is not kind:
                    return
                self._tl_i = i + 1
                ev = Event(t, kind, nxt_tl[3], nxt_tl[4], seq=nxt_tl[1])
            elif nxt_h is not None:
                if nxt_h[0] != t or nxt_h[2].kind is not kind:
                    return
                heapq.heappop(heap)
                ev = nxt_h[2]
            else:
                return
            self._processed += 1
            if trace is not None:
                trace(ev)
            out.append(ev)

    @property
    def pending(self) -> int:
        return len(self._heap) + len(self._timeline) - self._tl_i

    @property
    def processed(self) -> int:
        return self._processed
