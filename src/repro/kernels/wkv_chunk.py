"""Fused chunked-WKV6 Pallas kernel (the next lever from §Perf hillclimb 2).

Implements the chunked-parallel RWKV6 recurrence (see models/rwkv6.py
`_wkv_chunked`) with the whole per-chunk working set — r/k/v/decay tiles,
the (C, C) intra-chunk attention and the (hs, hs) running state — resident
in VMEM across all three chunk matmuls.  The XLA version materializes each
intermediate at a fusion boundary; this kernel's HBM traffic is exactly the
r/k/v/w/y streams, which is what the §Perf projection (t_m ≈ 1.5–2 s for
rwkv6 x train_4k) assumes.

Grid: (B*H, nb) — chunks are the sequential (carry) dimension; the state
lives in an f32 VMEM scratch across chunk steps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                C: int, hs: int, nb: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[...].astype(jnp.float32)          # (C, hs)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)          # (1, hs)
    S = s_ref[...]                              # (hs, hs)

    clw = jnp.cumsum(lw, axis=0)
    cw_prev = jnp.exp(clw - lw)                 # prod_{s<t} w_s
    r_dec = r * cw_prev
    k_dec = k * jnp.exp(jnp.minimum(-clw, 60.0))

    # inter-chunk + intra-chunk + bonus
    y = jax.lax.dot_general(r_dec, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    att = jax.lax.dot_general(r_dec, k_dec, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    att = jnp.where(ti > tj, att, 0.0)
    y = y + jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    bonus = jnp.sum(r * u * k, axis=1, keepdims=True)
    y = y + bonus * v
    o_ref[...] = y.astype(o_ref.dtype)

    # state propagation to chunk exit
    cw_last = jnp.exp(clw[-1:, :])              # (1, hs)
    k_carry = k * (cw_last * jnp.exp(jnp.minimum(-clw, 60.0)))
    s_ref[...] = S * cw_last.T + jax.lax.dot_general(
        k_carry, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_chunked(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, *, chunk: int = 16,
                interpret: bool = True) -> jax.Array:
    """r/k/v/w (B,T,H,hs), u (H,hs) -> y (B,T,H,hs).  w = per-step decay
    in (0,1); zero initial state (training from sequence start)."""
    B, T, H, hs = r.shape
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    nb = T // C

    def prep(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, hs)

    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-30))
    ur = jnp.broadcast_to(u[None], (B, H, hs)).reshape(B * H, 1, hs)

    kernel = functools.partial(_wkv_kernel, C=C, hs=hs, nb=nb)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nb),
        in_specs=[
            pl.BlockSpec((None, C, hs), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((None, C, hs), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((None, C, hs), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((None, C, hs), lambda bh, ic: (bh, ic, 0)),
            pl.BlockSpec((None, 1, hs), lambda bh, ic: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, C, hs), lambda bh, ic: (bh, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, hs), r.dtype),
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(prep(r), prep(k), prep(v), prep(lw), ur)
    return out.reshape(B, H, T, hs).transpose(0, 2, 1, 3)
