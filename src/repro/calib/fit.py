"""The calibrate() flow: sample → measure → fit → evaluate → persist.

For each operator the oracle supplies ground-truth seconds on a training
grid; a RandomForest is fit in log-space on the operator's feature vector
(``opmodels/features.py``); and the fitted model is scored on a disjoint
held-out grid against the two baselines the paper compares to:

- ``analytical``   the roofline OperatorModelSet (max(flops, bytes) + c)
- ``vidur_proxy``  the sqrt-homogenization proxy over the same kernels

reporting MAPE / p50 / p99 relative error per family — the fitted model
must beat both on heterogeneous batches, which is the repo's tracked
fidelity claim (FIDELITY.json).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.calib.artifacts import (
    CalibrationArtifact, CalibrationError, save_artifact,
)
from repro.calib.grid import CalibGrid, build_grid
from repro.calib.oracle import Oracle, resolve_oracle
from repro.core.hardware import HARDWARE, HardwareSpec
from repro.core.opmodels.analytical import OperatorModelSet
from repro.core.opmodels.calibration import (
    FittedAttention, FittedGroupedGemm,
)
from repro.core.opmodels.features import (
    attention_features, grouped_gemm_features,
)
from repro.core.opmodels.forest import RandomForest
from repro.core.opmodels.kernelsim import VirtualKernels
from repro.core.opmodels.vidur_proxy import VidurProxyModel


@dataclass
class CalibrationResult:
    model: str
    hardware: str
    oracle: str
    smoke: bool
    seed: int
    n_train: int
    n_eval: int
    limits: Dict[str, int]
    # operator -> family -> {mape, p50, p99, n}
    fidelity: Dict[str, Dict[str, Dict[str, float]]]
    artifacts: Dict[str, CalibrationArtifact] = field(default_factory=dict)
    artifact_paths: Dict[str, str] = field(default_factory=dict)
    wall_s: float = 0.0


def _resolve_hw(hardware) -> HardwareSpec:
    if isinstance(hardware, HardwareSpec):
        return hardware
    if hardware not in HARDWARE:
        raise CalibrationError(f"unknown hardware {hardware!r}; "
                               f"available: {sorted(HARDWARE)}")
    return HARDWARE[hardware]


def _stats(rel: List[float]) -> Dict[str, float]:
    a = np.asarray(rel, np.float64)
    return {"mape": float(a.mean()), "p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)), "n": int(a.size)}


def _fit_forest(X: List[np.ndarray], y: List[float],
                seed: int) -> RandomForest:
    return RandomForest(seed=seed).fit(np.asarray(X), np.asarray(y))


def calibrate(model: str = "qwen2-7b",
              hardware="A800-SXM4-80G",
              oracle="auto", *,
              smoke: bool = False,
              n_train: int = 400,
              n_eval: int = 120,
              seed: int = 0,
              max_len: Optional[int] = None,
              max_batch: Optional[int] = None,
              window: int = 0,
              out_root: Optional[str] = "artifacts/calib",
              ) -> CalibrationResult:
    """Fit per-operator models for (model, hardware) against an oracle and
    score them on a held-out grid.  ``out_root=None`` skips persisting
    (benchmark mode)."""
    from repro.configs import get_config
    t0 = time.perf_counter()
    cfg = get_config(model, smoke=smoke)
    hw = _resolve_hw(hardware)
    orc: Oracle = resolve_oracle(oracle, hw)
    limits = orc.limits()
    grid = build_grid(cfg, n_train=n_train, n_eval=n_eval, seed=seed,
                      limits=limits, max_len=max_len, max_batch=max_batch)
    analytical = OperatorModelSet(hw)
    vidur = VidurProxyModel(VirtualKernels(hw))
    g = grid.geometry
    result = CalibrationResult(
        model=cfg.name, hardware=hw.name, oracle=orc.name, smoke=smoke,
        seed=seed, n_train=n_train, n_eval=n_eval, limits=dict(limits),
        fidelity={})

    # ---------------------------------------------------------- attention --
    X, y = [], []
    for s in grid.attn_train:
        t = orc.attention(s.q_lens, s.kv_lens, g["n_heads"],
                          g["n_kv_heads"], g["head_dim"],
                          causal=s.causal, window=window)
        X.append(attention_features(s.q_lens, s.kv_lens, g["n_heads"],
                                    g["n_kv_heads"], g["head_dim"],
                                    causal=s.causal, window=window))
        y.append(math.log(max(t, 1e-9)))
    fitted_attn = FittedAttention(_fit_forest(X, y, seed), g["n_heads"],
                                  g["n_kv_heads"], g["head_dim"])

    rel: Dict[str, List[float]] = {"fitted": [], "analytical": [],
                                   "vidur_proxy": []}
    for s in grid.attn_eval:
        truth = orc.attention(s.q_lens, s.kv_lens, g["n_heads"],
                              g["n_kv_heads"], g["head_dim"],
                              causal=s.causal, window=window)
        preds = {
            "fitted": fitted_attn.predict(s.q_lens, s.kv_lens,
                                          causal=s.causal, window=window),
            "analytical": (
                analytical.attention_decode(s.kv_lens, g["n_heads"],
                                            g["n_kv_heads"], g["head_dim"],
                                            window=window)
                if s.decode else
                analytical.attention_prefill(s.q_lens, s.kv_lens,
                                             g["n_heads"], g["n_kv_heads"],
                                             g["head_dim"], causal=s.causal,
                                             window=window)),
            "vidur_proxy": (
                vidur.attention_decode(s.kv_lens, g["n_heads"],
                                       g["n_kv_heads"], g["head_dim"],
                                       window=window)
                if s.decode else
                vidur.attention_prefill(s.q_lens, s.kv_lens, g["n_heads"],
                                        g["n_kv_heads"], g["head_dim"],
                                        causal=s.causal, window=window)),
        }
        for fam, p in preds.items():
            rel[fam].append(abs(p - truth) / max(truth, 1e-12))
    result.fidelity["attention"] = {f: _stats(v) for f, v in rel.items()}
    result.artifacts["attention"] = CalibrationArtifact(
        operator="attention", hardware=hw.name, model=cfg.name,
        oracle=orc.name, geometry=dict(g), seed=seed, n_train=n_train,
        metrics=dict(result.fidelity["attention"]["fitted"]),
        forest=fitted_attn.forest.to_dict())

    # ------------------------------------------------------- grouped gemm --
    if grid.moe_geometry is not None:
        mg = grid.moe_geometry
        X, y = [], []
        for s in grid.gg_train:
            t = orc.grouped_gemm(s.tokens_per_expert, mg["d_in"],
                                 mg["d_out"])
            X.append(grouped_gemm_features(s.tokens_per_expert, mg["d_in"],
                                           mg["d_out"]))
            y.append(math.log(max(t, 1e-9)))
        fitted_gg = FittedGroupedGemm(_fit_forest(X, y, seed), mg["d_in"],
                                      mg["d_out"])
        rel = {"fitted": [], "analytical": [], "vidur_proxy": []}
        for s in grid.gg_eval:
            truth = orc.grouped_gemm(s.tokens_per_expert, mg["d_in"],
                                     mg["d_out"])
            preds = {
                "fitted": fitted_gg.predict(s.tokens_per_expert),
                "analytical": analytical.grouped_gemm(
                    s.tokens_per_expert, mg["d_in"], mg["d_out"]),
                "vidur_proxy": vidur.grouped_gemm(
                    s.tokens_per_expert, mg["d_in"], mg["d_out"]),
            }
            for fam, p in preds.items():
                rel[fam].append(abs(p - truth) / max(truth, 1e-12))
        result.fidelity["grouped_gemm"] = {f: _stats(v)
                                           for f, v in rel.items()}
        result.artifacts["grouped_gemm"] = CalibrationArtifact(
            operator="grouped_gemm", hardware=hw.name, model=cfg.name,
            oracle=orc.name, geometry=dict(mg), seed=seed, n_train=n_train,
            metrics=dict(result.fidelity["grouped_gemm"]["fitted"]),
            forest=fitted_gg.forest.to_dict())

    # -------------------------------------------------------------- persist --
    if out_root is not None:
        stamp = time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime())
        for art in result.artifacts.values():
            art.created_at = stamp
            result.artifact_paths[art.operator] = save_artifact(art,
                                                                out_root)
    result.wall_s = time.perf_counter() - t0
    return result
