"""Deterministic discrete-event simulation engine.

Events are ordered by (time, seq) — seq is a global monotone counter so
simultaneous events replay in schedule order, making every simulation
bit-reproducible (property-tested).
"""
from __future__ import annotations

import heapq
from typing import Callable, List, Optional

from repro.core.events import EV, Event


class SimEngine:
    def __init__(self, *, trace: Optional[Callable[[Event], None]] = None,
                 max_events: int = 50_000_000):
        self.now = 0.0
        self._heap: List[Event] = []
        self._trace = trace
        self._processed = 0
        self._max_events = max_events

    # ------------------------------------------------------------------ API
    def at(self, time: float, kind: EV, fn: Callable[[Event], None],
           **data) -> Event:
        assert time >= self.now - 1e-12, (time, self.now)
        ev = Event(time=max(time, self.now), kind=kind, fn=fn, data=data)
        heapq.heappush(self._heap, ev)
        return ev

    def after(self, delay: float, kind: EV, fn: Callable[[Event], None],
              **data) -> Event:
        return self.at(self.now + max(delay, 0.0), kind, fn, **data)

    def run(self, until: float = float("inf")) -> None:
        while self._heap:
            ev = self._heap[0]
            if ev.time > until:
                break
            heapq.heappop(self._heap)
            self.now = ev.time
            self._processed += 1
            if self._processed > self._max_events:
                raise RuntimeError("simulation event budget exceeded")
            if self._trace is not None:
                self._trace(ev)
            if ev.fn is not None:
                ev.fn(ev)
        if self._heap and self._heap[0].time > until:
            self.now = until

    @property
    def pending(self) -> int:
        return len(self._heap)

    @property
    def processed(self) -> int:
        return self._processed
