import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not move them.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.launch import hlo_cost  # noqa: E402
from repro.launch.lowering import build_step, lower_step  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

ARTIFACT_DIR = Path(os.environ.get("REPRO_ARTIFACTS", "artifacts")) / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             remat: str = "none", tag: str = "", options: dict = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    bundle = build_step(cfg, mesh, shape, remat=remat, options=options)
    lowered = lower_step(bundle, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    corrected = hlo_cost.analyze(txt)
    n_chips = mesh.devices.size

    # memory_analysis() prints per-device stats — record the key fields
    mem_rec = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "generated_code_bytes": mem.generated_code_size_in_bytes,
    }
    print(f"[{arch} x {shape_name} x {'multipod' if multi_pod else 'pod'}] "
          f"compiled in {t2 - t1:.1f}s (lower {t1 - t0:.1f}s)")
    print("  memory_analysis:", mem_rec)
    print("  cost_analysis: flops=%.3e bytes=%.3e" % (
        ca.get("flops", 0.0), ca.get("bytes accessed", 0.0)))
    print("  scan-corrected: flops=%.3e bytes=%.3e coll=%.3e" % (
        corrected["flops"], corrected["bytes"], corrected["collective_bytes"]))

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multipod" if multi_pod else "pod",
        "status": "ok",
        "tag": tag,
        "n_chips": n_chips,
        "step": bundle.name,
        "meta": bundle.meta,
        "lower_s": t1 - t0,
        "compile_s": t2 - t1,
        "memory_analysis": mem_rec,
        "cost_analysis_raw": {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))},
        "hlo_corrected": {k: float(v) for k, v in corrected.items()},
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="Multi-pod dry-run driver")
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_IDS} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--remat", default="none")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--attn", default="naive", choices=["naive", "blockwise"])
    ap.add_argument("--attn-block", type=int, default=1024)
    ap.add_argument("--rwkv", default="scan", choices=["scan", "chunked"])
    ap.add_argument("--rwkv-chunk", type=int, default=16)
    ap.add_argument("--moe", default="psum", choices=["psum", "a2a"])
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    args = ap.parse_args()
    options = {"attn_impl": args.attn, "attn_block": args.attn_block,
               "rwkv_impl": args.rwkv, "rwkv_chunk": args.rwkv_chunk,
               "moe_dispatch": args.moe}

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multipod" if mp else "pod"
                fname = outdir / f"{arch}__{shape}__{mesh_name}__{args.tag}.json"
                try:
                    rec = run_cell(arch, shape, mp, remat=args.remat,
                                   tag=args.tag, options=options)
                except Exception as e:  # a failing cell is a bug — record it
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures.append((arch, shape, mesh_name))
                fname.write_text(json.dumps(rec, indent=2))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete:", outdir)


if __name__ == "__main__":
    main()
