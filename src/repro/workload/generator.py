"""Workload generation: request traces with configurable arrivals/lengths."""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.request import Request

ARRIVALS = ("poisson", "uniform", "burst", "closed")
RATE_CURVES = ("diurnal",)


@dataclass
class WorkloadConfig:
    n_requests: int = 100
    arrival: str = "poisson"            # "poisson" | "uniform" | "burst" | "closed"
    rate: float = 4.0                   # requests/s (open-loop)
    prompt: str = "lognormal"           # "fixed" | "uniform" | "lognormal" | "bimodal"
    prompt_mean: int = 512
    prompt_max: int = 8192
    output: str = "lognormal"
    output_mean: int = 128
    output_max: int = 2048
    # burst arrivals: bursts of burst_size requests every burst_period sec
    burst_size: int = 32
    burst_period: float = 1.0
    # closed-loop arrivals: at most `concurrency` requests in flight; the
    # next request is injected when a slot frees (controller-driven — the
    # generator only stamps placeholder t=0 arrivals, re-stamped at run time)
    concurrency: Optional[int] = None
    # shared-prefix traces (prefix caching has something to hit): each
    # request joins one of `prefix_groups` system-prompt groups and its
    # prompt is prefix_len shared tokens + the drawn unique suffix
    prefix_groups: int = 0
    prefix_len: int = 0
    # multi-turn conversations: n_requests are grouped into conversations
    # of `turns` turns; turn t's prompt is the full history (a growing
    # shared prefix) + a fresh drawn user turn, arriving turn_gap apart.
    # Open-loop approximation: turns arrive on the fixed gap even if the
    # previous turn is still decoding — pick turn_gap above the expected
    # per-turn latency, or the growing prefix will not be cached yet and
    # the history prefills as fresh compute (hit rates degrade honestly
    # under congestion, as an impatient client's would)
    turns: int = 1
    turn_gap: float = 5.0
    # fleet-scale arrival shaping: "diurnal" modulates the poisson rate
    # sinusoidally — lambda(t) = rate * (1 + amplitude*sin(2*pi*t/period)) —
    # so autoscalers have a realistic load swing to chase.  Arrivals come
    # from the exact non-homogeneous process via time rescaling (unit-rate
    # exponential gaps inverted through the integrated rate), not thinning,
    # so the trace is deterministic in the seed.
    rate_curve: Optional[str] = None      # None | "diurnal"
    rate_period: float = 60.0             # seconds per diurnal cycle
    rate_amplitude: float = 0.5           # relative swing, in [0, 1)
    seed: int = 0


def _lengths(kind: str, mean: int, maxv: int, n: int,
             rng: np.random.Generator) -> np.ndarray:
    if kind == "fixed":
        return np.full(n, mean, np.int64)
    if kind == "uniform":
        return rng.integers(1, 2 * mean, n)
    if kind == "bimodal":
        short = rng.integers(max(mean // 8, 1), mean // 2, n)
        long_ = rng.integers(mean * 2, mean * 4, n)
        pick = rng.random(n) < 0.7
        return np.where(pick, short, long_)
    # lognormal with mean ~= mean (ShareGPT-ish heavy tail)
    sigma = 1.0
    mu = np.log(mean) - sigma ** 2 / 2
    v = rng.lognormal(mu, sigma, n)
    return np.clip(v.astype(np.int64), 1, maxv)


def _diurnal_arrivals(cfg: WorkloadConfig, n: int,
                      rng: np.random.Generator) -> np.ndarray:
    """Non-homogeneous poisson arrivals under the diurnal rate curve.

    Time rescaling: draw unit-rate exponential targets s_i, then invert the
    integrated rate Lambda(t) = rate*(t + A*P/(2*pi)*(1 - cos(2*pi*t/P)))
    by (vectorized) bisection — Lambda is strictly increasing for A < 1.
    """
    a, period, rate = cfg.rate_amplitude, cfg.rate_period, cfg.rate
    if a <= 0:
        gaps = rng.exponential(1.0 / rate, n)
        return np.cumsum(gaps)
    targets = np.cumsum(rng.exponential(1.0, n))
    w = 2.0 * np.pi / period

    def big_lambda(t):
        return rate * (t + a / w * (1.0 - np.cos(w * t)))

    lo = np.zeros(n)
    # lambda(t) >= rate*(1-a) everywhere, so t <= s / (rate*(1-a))
    hi = targets / (rate * (1.0 - a)) + period
    for _ in range(64):           # ~2e-19 relative interval after 64 halvings
        mid = 0.5 * (lo + hi)
        below = big_lambda(mid) < targets
        lo = np.where(below, mid, lo)
        hi = np.where(below, hi, mid)
    return 0.5 * (lo + hi)


def generate(cfg: WorkloadConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_requests
    if cfg.rate_curve is not None and cfg.rate_curve not in RATE_CURVES:
        raise ValueError(f"unknown rate_curve {cfg.rate_curve!r}; "
                         f"known: {RATE_CURVES}")
    if cfg.rate_curve == "diurnal":
        if cfg.arrival != "poisson":
            raise ValueError("rate_curve='diurnal' modulates the poisson "
                             f"arrival process; got arrival={cfg.arrival!r}")
        if not 0.0 <= cfg.rate_amplitude < 1.0:
            # amplitude >= 1 makes the integrated rate non-invertible
            # (lambda touches zero) — fail instead of emitting inf/garbage
            raise ValueError(f"rate_amplitude must be in [0, 1), "
                             f"got {cfg.rate_amplitude}")
        if cfg.rate_period <= 0:
            raise ValueError(f"rate_period must be > 0, "
                             f"got {cfg.rate_period}")
    if cfg.arrival == "poisson":
        if cfg.rate_curve == "diurnal":
            arrivals = _diurnal_arrivals(cfg, n, rng)
        else:
            gaps = rng.exponential(1.0 / cfg.rate, n)
            arrivals = np.cumsum(gaps)
    elif cfg.arrival == "uniform":
        arrivals = np.sort(rng.uniform(0, n / cfg.rate, n))
    elif cfg.arrival == "burst":
        # ramp of bursts: burst_size simultaneous requests every burst_period
        size = max(int(cfg.burst_size), 1)
        arrivals = (np.arange(n) // size) * max(cfg.burst_period, 0.0)
    elif cfg.arrival == "closed":
        if cfg.concurrency is not None and cfg.concurrency < 1:
            raise ValueError(f"closed-loop concurrency must be >= 1, "
                             f"got {cfg.concurrency}")
        # placeholders: the controller injects request i+concurrency when
        # request i completes (see GlobalController.submit_closed)
        arrivals = np.zeros(n)
    else:
        raise ValueError(f"unknown arrival process {cfg.arrival!r}; "
                         f"known: {ARRIVALS}")
    plens = _lengths(cfg.prompt, cfg.prompt_mean, cfg.prompt_max, n, rng)
    olens = _lengths(cfg.output, cfg.output_mean, cfg.output_max, n, rng)
    if cfg.turns > 1 and cfg.prefix_groups > 0:
        raise ValueError("turns > 1 and prefix_groups > 0 are mutually "
                         "exclusive workload shapes")
    if cfg.turns > 1:
        return _multiturn(cfg, arrivals, plens, olens)
    reqs = [Request(rid=i, arrival=float(arrivals[i]),
                    prompt_len=int(plens[i]), output_len=max(int(olens[i]), 1))
            for i in range(n)]
    if cfg.prefix_groups > 0:
        # drawn AFTER lengths so prefix-free workloads replay bit-for-bit
        groups = rng.integers(0, cfg.prefix_groups, n)
        for r, g in zip(reqs, groups):
            r.prefix_id = int(g)
            r.prefix_len = int(cfg.prefix_len)
            r.prompt_len += int(cfg.prefix_len)   # shared system prompt
    return reqs


def _multiturn(cfg: WorkloadConfig, arrivals, plens, olens) -> List[Request]:
    """Conversation traces: consecutive turns share an ever-growing prefix
    (the full prior history), the natural prey of a radix prefix cache."""
    n, turns = cfg.n_requests, cfg.turns
    n_conv = max((n + turns - 1) // turns, 1)
    reqs: List[Request] = []
    rid = 0
    for c in range(n_conv):
        # conversation c starts when its first request would have arrived,
        # preserving the configured offered rate in requests/s (starting
        # every conversation at arrivals[c] would multiply load by `turns`)
        at = float(arrivals[min(c * turns, n - 1)])
        history = 0
        for _ in range(turns):
            if rid >= n:
                break
            prompt = history + int(plens[rid])
            out = max(int(olens[rid]), 1)
            reqs.append(Request(
                rid=rid, arrival=at, prompt_len=prompt, output_len=out,
                prefix_id=1_000_000 + c, prefix_len=history))
            history = prompt + out
            at += max(cfg.turn_gap, 0.0)
            rid += 1
    return reqs


def fixed_batch(n: int, prompt_len: int, output_len: int) -> List[Request]:
    """The paper's Table-2 style workload: B requests, fixed lens, t=0."""
    return [Request(rid=i, arrival=0.0, prompt_len=prompt_len,
                    output_len=output_len) for i in range(n)]


def load_trace(path: str, *, n_requests: Optional[int] = None) -> List[Request]:
    """Replay a request trace from a JSONL file.

    Each line is an object with ``prompt_len`` and ``output_len`` (ints)
    and optionally ``arrival`` (seconds; missing -> 0.0).  Arrival times
    are shifted so the trace starts at its earliest arrival.
    """
    rows = []
    with open(path) as f:
        for ln, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
                rows.append((float(obj.get("arrival", 0.0)),
                             int(obj["prompt_len"]), int(obj["output_len"])))
            except (ValueError, KeyError, TypeError) as e:
                raise ValueError(
                    f"{path}:{ln + 1}: bad trace record ({e}); expected "
                    f'{{"arrival": float, "prompt_len": int, '
                    f'"output_len": int}}') from e
    if n_requests is not None:
        rows = rows[:n_requests]
    if not rows:
        raise ValueError(f"{path}: empty trace")
    t0 = min(a for a, _, _ in rows)
    return [Request(rid=i, arrival=a - t0, prompt_len=p,
                    output_len=max(o, 1))
            for i, (a, p, o) in enumerate(sorted(rows))]
