"""``run_fleet(spec) -> FleetReport``: execute a fleet SimSpec.

The FleetReport aggregates per-instance Reports (summary + cluster
breakdown per instance) under fleet-level metrics: per-tenant SLO
attainment, routing imbalance, the scale-event log, and provisioned-but-
idle GPU-seconds.  Its surface mirrors :class:`repro.api.run.Report`
(``summary`` / ``spec_hash`` / ``save`` / item access), so the CLI, sweep
runner, and pareto helpers work on fleets unchanged.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from datetime import datetime, timezone
from typing import Any, Dict, List, Optional

from repro.api.run import ReportBase
from repro.core.engine import SimEngine
from repro.core.metrics import MetricsCollector, _mean, _pct, slo_attainment
from repro.fleet.controller import FleetController
from repro.fleet.instance import Instance


@dataclass
class FleetReport(ReportBase):
    """Typed result of one fleet simulation (JSON-serializable; shares
    Report's serialization surface via :class:`ReportBase`)."""
    name: str
    spec: Dict[str, Any]
    spec_hash: str
    summary: Dict[str, Any]
    instances: Dict[str, Dict[str, Any]]     # per-instance sub-reports
    tenants: Dict[str, Dict[str, Any]]       # per-tenant-class metrics
    scale_events: List[Dict[str, Any]]
    conservation: Dict[str, int]
    all_complete: bool
    n_devices: int                            # peak provisioned devices
    sim_events: int
    sim_duration_s: float
    wall_clock_s: float
    created_at: str
    point: Optional[Dict[str, Any]] = None    # sweep-axis assignment


# ------------------------------------------------------------- assembly --
def _instance_block(inst: Instance, spec) -> Dict[str, Any]:
    from repro.api.run import _cluster_breakdown
    ctrl = inst.controller
    # per-device stats use the instance's PEAK PROVISIONED devices (the
    # same basis as the fleet summary) — handle.n_devices would count
    # parked P:D standby replicas that never held GPUs
    summary = ctrl.metrics.report(
        n_devices=inst.peak_devices or inst.handle.n_devices,
        slo_ttft=spec.slo.ttft_s if spec.slo else None,
        slo_tpot=spec.slo.tpot_s if spec.slo else None)
    return {
        "group": inst.group.name,
        "state": inst.state,
        "devices": inst.peak_devices,
        "created_at_s": inst.created_at,
        "active_at_s": inst.active_at,
        "stopped_at_s": inst.stopped_at,
        "routed": inst.routed,
        "outstanding": inst.outstanding(),
        "gpu_seconds": inst.gpu_seconds,
        "busy_gpu_seconds": inst.busy_gpu_seconds(),
        "provisioned_dollars": inst.provisioned_dollars,
        "dollars_per_hour": inst.dollar_rate(),
        "summary": summary,
        "clusters": _cluster_breakdown(inst.handle),
        "conservation": ctrl.conservation_check(),
    }


def _tenant_block(spec, completed) -> Dict[str, Dict[str, Any]]:
    out: Dict[str, Dict[str, Any]] = {}
    for t in spec.fleet.tenants:
        mine = [r for r in completed if r.tenant == t.name]
        ttfts = [r.ttft() for r in mine if r.ttft() is not None]
        ttft = t.ttft_s if t.ttft_s is not None \
            else (spec.slo.ttft_s if spec.slo else None)
        tpot = t.tpot_s if t.tpot_s is not None \
            else (spec.slo.tpot_s if spec.slo else None)
        out[t.name] = {
            "n_completed": len(mine),
            "priority": t.priority,
            "ttft_p50_s": _pct(ttfts, 50),
            "ttft_p99_s": _pct(ttfts, 99),
            "ttft_mean_s": _mean(ttfts),
            "slo_ttft_s": ttft,
            "slo_tpot_s": tpot,
            "slo_attainment": slo_attainment(mine, ttft_s=ttft,
                                             tpot_s=tpot),
        }
    return out


def _routing_imbalance(instances: Dict[str, Instance]) -> Optional[float]:
    """Coefficient of variation of per-instance routed-request counts —
    0 means perfectly even; grows with hot-spotting."""
    counts = [i.routed for i in instances.values()]
    if not counts or sum(counts) == 0:
        return None
    mean = sum(counts) / len(counts)
    var = sum((c - mean) ** 2 for c in counts) / len(counts)
    return (var ** 0.5) / mean


# ------------------------------------------------------------------ run --
def run_fleet(spec, *, hardware=None, ops=None,
              engine_overhead=None, telemetry=None) -> FleetReport:
    """Validate, build, and run one fleet experiment (see module doc).

    ``telemetry`` injects a shared :class:`repro.obs.Telemetry` recorder
    spanning every instance (windowed sub-engines keep absolute sim time,
    so all spans merge on the global clock); ``None`` creates one iff
    ``spec.obs`` is enabled."""
    t0 = time.perf_counter()
    spec.validate()
    if telemetry is None and spec.obs is not None and spec.obs.enabled:
        from repro.obs import Telemetry
        telemetry = Telemetry.from_spec(spec.obs)
    engine = SimEngine()
    fc = FleetController(spec, engine, hardware=hardware, ops=ops,
                         engine_overhead=engine_overhead,
                         telemetry=telemetry)
    requests = spec.workload.build_requests(spec.seed)
    fc.submit_all(requests)
    until = spec.until if spec.until is not None else float("inf")
    if fc.windowed:
        from repro.fleet.windowed import run_windowed
        run_windowed(fc, until, spec.fleet.window_s)
    else:
        engine.run(until)
    fc.finalize()
    wall = time.perf_counter() - t0

    insts = fc.instances
    merged = MetricsCollector.merged(
        [i.controller.metrics for i in insts.values()])
    summary = merged.report(
        n_devices=fc.peak_devices,
        slo_ttft=spec.slo.ttft_s if spec.slo else None,
        slo_tpot=spec.slo.tpot_s if spec.slo else None)
    # fleet-level observables
    kinds = [e["kind"] for e in fc.scale_events]
    gpu_s = sum(i.gpu_seconds for i in insts.values())
    busy_s = sum(i.busy_gpu_seconds() for i in insts.values())
    # fleet $ accounting: each instance integrates its own provisioned-$
    # (heterogeneous hardware prices per cluster), so fleet $ == sum of
    # instance $ by construction — a property test pins this identity
    dollars = sum(i.provisioned_dollars for i in insts.values())
    idle_frac = max(gpu_s - busy_s, 0.0) / gpu_s if gpu_s > 0 else 0.0
    duration = float(summary.get("duration_s") or 0.0)
    tput = float(summary.get("throughput_tok_s") or 0.0)
    summary.update({
        "fleet_instances_built": len(insts),
        "fleet_instances_active_end": sum(
            1 for i in insts.values() if i.routable),
        "scale_up_events": kinds.count("scale_up"),
        "scale_down_events": kinds.count("scale_down"),
        "rebalance_events": kinds.count("rebalance"),
        "routing_imbalance": _routing_imbalance(insts),
        "provisioned_gpu_seconds": gpu_s,
        "idle_gpu_seconds": max(gpu_s - busy_s, 0.0),
        "provisioned_dollars": dollars,
        # $ paid for capacity that sat idle (idle-GPU-fraction of spend)
        "idle_dollars": dollars * idle_frac,
        # time-averaged fleet burn rate over the measured window
        "dollars_per_hour": (dollars / (duration / 3600.0)
                             if duration > 0 else 0.0),
        "tok_per_s_per_dollar": (
            tput / (dollars / (duration / 3600.0))
            if duration > 0 and dollars > 0 else None),
    })
    summary["fleet_engine_mode"] = spec.fleet.engine
    if spec.fleet.engine == "windowed":
        summary["fleet_window_s"] = spec.fleet.window_s
    # fleet prefix-cache hit rate (the prize cache-aware routing chases)
    # + predictor memo-cache effectiveness pooled across every replica
    hit = prompt = 0
    cache_hits = cache_misses = 0
    transfers: Dict[str, float] = {}
    for inst in insts.values():
        for cluster in inst.handle.clusters.values():
            for w in cluster.replicas:
                cache_hits += w.predictor.cache_hits
                cache_misses += w.predictor.cache_misses
                if w.memory is not None:
                    hit += w.memory.hit_tokens
                    prompt += w.memory.prompt_tokens
        ts = inst.controller.transfer_stats
        for k, v in ts.items():
            transfers[k] = transfers.get(k, 0.0) + v
    total_lookups = cache_hits + cache_misses
    summary["predictor_cache_hits"] = cache_hits
    summary["predictor_cache_misses"] = cache_misses
    summary["predictor_cache_hit_rate"] = \
        (cache_hits / total_lookups) if total_lookups else None
    if prompt:
        summary["prefix_hit_token_frac"] = hit / prompt
    if transfers.get("transfers"):
        summary["kv_transfer_count"] = transfers["transfers"]
        summary["kv_transfer_serial_s"] = transfers["serial_s"]
        summary["kv_transfer_exposed_s"] = transfers["exposed_s"]
    # shared-fabric contention, pooled across instances that model one
    fabrics = [i.handle.fabric for i in insts.values()
               if getattr(i.handle, "fabric", None) is not None]
    if fabrics:
        exposed = sum(f.exposed_comm_s() for f in fabrics)
        uncontended = sum(f.uncontended_comm_s() for f in fabrics)
        summary["fabric_transfers"] = sum(f.stats["transfers"]
                                          for f in fabrics)
        summary["fabric_exposed_comm_s"] = exposed
        summary["fabric_uncontended_comm_s"] = uncontended
        summary["fabric_contention_delay_s"] = exposed - uncontended
    tenants = _tenant_block(spec, merged.completed)
    attains = [t["slo_attainment"] for t in tenants.values()
               if t["slo_attainment"] is not None]
    if attains:
        summary["tenant_slo_attainment_min"] = min(attains)
    if telemetry is not None:
        summary.update(telemetry.summary_fields())
    conservation = fc.conservation_check()
    return FleetReport(
        name=spec.name,
        spec=spec.to_dict(),
        spec_hash=spec.spec_hash(),
        summary=summary,
        instances={n: _instance_block(i, spec) for n, i in insts.items()},
        tenants=tenants,
        scale_events=fc.scale_events,
        conservation=conservation,
        all_complete=(conservation == {"complete": len(requests)}),
        n_devices=fc.peak_devices,
        # windowed mode: the fleet engine plus every distinct sub-engine
        sim_events=sum(e.processed for e in
                       {id(engine): engine,
                        **{id(i.handle.engine): i.handle.engine
                           for i in insts.values()}}.values()),
        sim_duration_s=summary.get("duration_s", 0.0),
        wall_clock_s=wall,
        created_at=datetime.now(timezone.utc).isoformat(timespec="seconds"),
    )
